"""Batched multi-tenant solver-serving engine.

``SolverServeEngine`` turns a stream of per-tenant ``SolveRequest``s into a
small number of compiled batch solves:

  1. **Bucketing** — requests are grouped by padded power-of-two shape (and
     solver config), so the jit compile cache is bounded by the number of
     buckets seen, not the number of distinct request shapes.
  2. **Same-design coalescing** — requests whose design matrix fingerprints
     match are merged into ONE multi-RHS solve: ``y`` becomes (obs, k) and a
     single stream of ``x`` (the solver's entire memory traffic) serves all
     k tenants.  k is itself padded to a power of two to bound recompiles.
  3. **Same-bucket vmap batching** — leftover single-design requests in a
     bucket are stacked and solved with one vmapped call (batch padded to a
     power of two by replicating the last system; replicas are discarded).
  4. **Design caching** — everything that depends only on ``x`` lives on a
     ``repro.core.PreparedDesign`` handle (device copy, column norms,
     block-Gram Cholesky factors, sharded copies, warm coefficients),
     memoised across flushes in an LRU ``DesignCache``.  Solves dispatch
     through ``PreparedDesign.solve`` with the request's effective
     ``SolverSpec`` (see ``spec_for``), so the engine is a consumer of the
     public core API — methods registered via ``repro.core.register_method``
     are servable without engine changes.
  5. **Warm starts** — a request may carry initial coefficients
     (``SolveRequest.a0``), or name a ``tenant_id`` whose last solved
     coefficients the design cache retained; the iterative solvers then
     start from that point instead of zeros.  Warm and cold requests
     coalesce freely: cold members of a group ride a zero column/row of the
     stacked ``a0``, which is bit-identical to the cold path.
  6. **Mesh placement** — an engine constructed with a ``ServeMesh`` routes
     buckets onto the mesh-sharded SolveBakP backends
     (``repro.core.distributed``) by size: big buckets shard their design
     rows over the data axes (``obs_sharded``), giant same-design multi-RHS
     groups shard the k axis instead (``rhs_sharded`` — one stream of ``x``
     per device serves k/D tenants), and optionally pod-scale buckets go
     2-D.  The placement is part of the grouping key, so one compiled
     program never mixes mesh layouts; vmap batching stays single-device
     (vmapping over shard_map is not a thing), so sharded buckets solve
     their leftover singles individually.

  7. **Execution lanes** — ``flush()`` is a pure batch-builder: it groups,
     resolves design entries and routes each batch to its execution lane
     (``repro.serve.lanes`` — a (device set, kernel path) executor thread
     per placement/kernel family), then waits for all units.  Batches on
     different lanes (single-device xla, fused Pallas, each mesh
     placement) overlap; batches on one lane keep their submission order,
     so results are bit-identical to the sequential engine
     (``ServeConfig.lane_execution=False`` collapses everything onto one
     serial lane — the pre-lane architecture).

Results come back as per-request ``ServedSolve``s, in submission order, with
padding stripped and per-request SSE recomputed from the stripped residual.

Flushing is exception-safe: a batch whose solver raises is isolated — every
request in it gets an error result (``ServedSolve.error`` set, zero
coefficients) and the remaining batches still run, so one poisoned request
can never wedge the engine or starve its co-tenants.

Example::

    engine = SolverServeEngine()
    for x, y in workload:
        engine.submit(SolveRequest(x=x, y=y, method="bakp_gram", rtol=1e-8))
    for served in engine.flush():
        use(served.coef)
"""
from __future__ import annotations

import functools
import itertools
import logging
import math
import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.prepare import PreparedDesign
from repro.core.spec import SolverSpec, solver_method
from repro.kernels.fused_solve import fused_fits
from repro.resilience import faults, ladder
from repro.serve.batching import (group_requests, next_pow2, pad_x, pad_y,
                                  prepare_request, request_bucket)
from repro.serve.cache import DesignCache
from repro.serve.lanes import LaneKey, LanePool, LaneWork, current_lane
from repro.serve.placement import (Placement, PlacementPolicy, ServeMesh,
                                   placement_for_bucket, placement_for_group)
from repro.serve.types import ServedSolve, SolveRequest
from repro.store.store import TileCorruptionError

_log = logging.getLogger(__name__)

# BAK-family methods a store-backed engine rewrites to "bakp_stream" when a
# request's bucket exceeds the device byte budget (spec_for): same
# block-Jacobi mathematics, served through the store's streaming path
# instead of a resident X copy that could never be admitted.
_STREAM_REROUTE = frozenset(
    {"bak", "bakp", "bakp_gram", "bakp_fused", "bak_fused"})


@dataclass
class ServeConfig:
    """Engine-level knobs (per-request solver knobs live on SolveRequest)."""

    omega: float = 1.0
    ridge: float = 1e-6
    min_obs: int = 8
    min_vars: int = 8
    coalesce: bool = True        # same-design requests → one multi-RHS solve
    vmap_batch: bool = True      # same-bucket singles → one vmapped solve
    max_vmap_batch: int = 64     # cap on vmapped batch size (memory bound)
    cache_entries: int = 64      # LRU design-cache capacity
    warm_cache: bool = True      # retain per-tenant coefs for warm starts
    warm_tenants: int = 64       # per-design LRU cap on retained tenants
    prefer_fused: bool = False   # upgrade "bakp" requests to the fused
    # whole-solve megakernel ("bakp_fused") when the bucket fits VMEM.
    # Same algorithm/results; trades cross-design vmap batching for the
    # fused kernel's one-launch solves, so it pays off on coalescing-heavy
    # (repeated-design) traffic.  Mesh engines keep "bakp" (the fused
    # kernel is single-device; upgrading would defeat sharded placement).
    placement_policy: Optional[PlacementPolicy] = None  # None → defaults
    omega_2d: float = 0.5        # damping for the 2-D mesh placement (its
    # cross-device Jacobi block is D·thr wide — see core.distributed)
    precision: Optional[str] = None  # engine-level X-stream precision policy
    # ("bf16"/"bf16_fp32acc"): applied to legacy per-field requests exactly
    # like omega/ridge (an explicit SolveRequest.spec stays authoritative).
    # Requests whose effective method lacks the precision downgrade to
    # "fp32" with a solver_fallback_total{reason="precision"} count instead
    # of erroring their batch (see spec_for).
    lane_execution: bool = True  # run flush batches on per-placement
    # execution lanes (repro.serve.lanes) so single-device xla/fused and
    # mesh-sharded solves overlap.  False collapses every lane onto ONE
    # serial executor thread — the pre-lane architecture, kept as the
    # benchmark baseline and a conservative fallback.  Results are
    # bit-identical either way (batch composition and per-batch execution
    # are unchanged; only cross-batch overlap differs).
    store_device_bytes: Optional[int] = None  # device-tier byte budget for
    # the design store (repro.store).  With any store_* knob set, the
    # design cache becomes a view over a tiered DesignStore: eviction
    # demotes (device → host RAM → disk) instead of deleting, demoted
    # designs promote back with warm-start/Cholesky state intact, and
    # requests whose bucket exceeds this budget are rewritten to the
    # streaming "bakp_stream" method (counted as solver_fallback_total
    # {reason="over_hbm"}).  All three None (default) = no store; behaviour
    # and results are bit-identical to the plain LRU cache.
    store_host_bytes: Optional[int] = None    # host-tier budget; overflow
    # spills LRU host snapshots to disk (or drops X bytes, state kept,
    # when store_dir is unset)
    store_dir: Optional[str] = None           # disk-tier directory for the
    # memmapped design tile files; None disables the disk tier
    fault_plan: Optional[object] = None  # chaos harness (repro.resilience):
    # a FaultPlan, a {site: rule} dict, inline JSON text or a JSON file
    # path.  Installed process-wide at engine construction; None (default)
    # leaves injection disarmed — the hooks are a single None-check, so
    # behaviour is bit-identical to a build without them.
    retry_ladder: bool = True    # retry failed/diverged solves down the
    # capability-aware degradation ladder (repro.resilience.ladder): cold
    # restart when a warm start is implicated, fp32 when reduced precision
    # is, then MethodEntry.fallback hops (fused → persweep → stream →
    # lstsq).  False restores the pre-ladder behaviour: first error fails
    # the batch.
    max_retries: int = 3         # ladder steps per request (not per rung)
    retry_backoff_s: float = 0.002  # jittered exponential backoff base
    # between ladder steps; 0 disables the sleep (tests)
    lane_max_restarts: int = 3   # consecutive lane worker-thread deaths
    # before that lane's circuit breaker trips and its work reroutes to
    # the serial fallback executor (repro.serve.lanes)


@dataclass
class ServeStats:
    """Per-engine counters.

    A convenience view: the same events stream into the engine's
    ``repro.obs`` ``MetricsRegistry`` (``serve_*`` families, richer —
    labelled by method/kernel path/placement and with latency and sweep
    histograms the plain ints here cannot carry), which is what the
    exporters read.  These fields stay per-instance ints so multiple
    engines in one process (tests, benchmarks) keep independent tallies
    with zero-cost reads.
    """

    requests: int = 0
    solver_calls: int = 0
    multi_rhs_groups: int = 0
    multi_rhs_requests: int = 0
    vmap_batches: int = 0
    vmap_requests: int = 0
    single_solves: int = 0
    warm_starts: int = 0
    failures: int = 0
    sharded_solves: int = 0      # solver calls routed to a mesh placement
    retries: int = 0             # retry-ladder steps taken (all reasons)

    def as_dict(self) -> dict:
        return dict(self.__dict__)


@functools.lru_cache(maxsize=32)
def _vmapped_solver(spec: SolverSpec, warm: bool):
    """jit(vmap(...)) batch solver for one static solver config.

    ``spec`` must be canonical with ``atol`` zeroed (the engine passes
    ``spec.canonical().replace(atol=0.0)``): ``atol`` is a *traced
    per-element* argument, not part of the cache key — requests in one
    bucket can have different real obs, so each gets its own
    padding-corrected absolute tolerance without recompiling.  The
    per-system callable comes from the method's registry entry
    (``MethodEntry.vmap_one``), so registered backends become batchable by
    providing one.  Module-level lru_cache keeps the function object (and
    therefore the jit compile cache) stable across engine instances and
    flushes; the bounded maxsize caps memory when tenants send many
    distinct knob combinations.  ``warm`` selects the variant that threads
    a batched ``a0`` through — kept out of the cold signature so all-cold
    batches keep their original program.
    """
    entry = solver_method(spec.method)
    if entry.vmap_one is None:
        raise ValueError(f"method {spec.method!r} is not vmap-batchable")
    one = entry.vmap_one(spec)
    if warm:
        return jax.jit(jax.vmap(one))
    return jax.jit(jax.vmap(functools.partial(one, a0=None)))


class SolverServeEngine:
    """Multi-tenant batched serving front-end for the BAK solver family.

    ``mesh`` (optional) is a ``repro.serve.placement.ServeMesh`` (or a raw
    ``jax.sharding.Mesh``, wrapped with its first axis as data); with one,
    the placement policy routes big buckets/groups onto the mesh-sharded
    solvers.  Without one (default) every solve is single-device, exactly
    as before.
    """

    def __init__(self, config: Optional[ServeConfig] = None, mesh=None,
                 registry: Optional[obs.MetricsRegistry] = None):
        self.config = config or ServeConfig()
        if mesh is not None and not isinstance(mesh, ServeMesh):
            axes = tuple(mesh.axis_names)
            model = "model" if "model" in axes and len(axes) > 1 else None
            data = tuple(a for a in axes if a != model)
            mesh = ServeMesh(mesh=mesh, data_axes=data, model_axis=model)
        self.mesh: Optional[ServeMesh] = mesh
        self.policy = self.config.placement_policy or PlacementPolicy()
        # One registry for the whole serving stack: the cache and (in the
        # async path) the dispatcher record into this same instance, so one
        # exporter snapshot covers intake → cache → solve.  Defaults to the
        # process-global registry; pass a fresh MetricsRegistry to isolate
        # (benchmarks comparing engine variants do).
        self.registry = registry or obs.default_registry()
        cfg = self.config
        if cfg.fault_plan is not None:
            # Chaos harness: arm the process-wide plan.  Engines without
            # one never touch the module global, so a fresh engine does not
            # disarm a plan a test installed directly.
            faults.install(faults.FaultPlan.coerce(cfg.fault_plan))
        if (cfg.store_device_bytes is not None
                or cfg.store_host_bytes is not None
                or cfg.store_dir is not None):
            from repro.store import DesignStore
            self.store = DesignStore(device_bytes=cfg.store_device_bytes,
                                     host_bytes=cfg.store_host_bytes,
                                     disk_dir=cfg.store_dir,
                                     max_entries=cfg.cache_entries,
                                     registry=self.registry)
        else:
            self.store = None
        self.cache = DesignCache(max_entries=self.config.cache_entries,
                                 max_tenants=self.config.warm_tenants,
                                 registry=self.registry,
                                 store=self.store)
        # The engine owns its lane pool: the synchronous flush and the
        # async dispatcher submit into the same executors, so per-lane
        # program affinity (and the per-lane gauges) cover both paths.
        self.lanes = LanePool(registry=self.registry,
                              serial=not self.config.lane_execution,
                              max_restarts=self.config.lane_max_restarts)
        # Work units on different lanes mutate ServeStats concurrently.
        self._stats_lock = threading.Lock()
        self._warned_unshardable_fused = False
        self.stats = ServeStats()
        reg = self.registry
        self._m_requests = reg.counter(
            "serve_requests_total", "requests accepted into flush windows")
        self._m_solves = reg.counter(
            "serve_solves_total",
            "solver calls by batch kind / method / kernel path / placement")
        self._m_served = reg.counter(
            "serve_requests_served_total",
            "requests answered, by batch kind and warm/cold start")
        self._m_errors = reg.counter(
            "serve_errors_total",
            "requests failed, by exception type / method / bucket")
        self._m_latency = reg.histogram(
            "serve_solve_latency_seconds",
            "wall time of one batched solver call (kernel path, X-stream "
            "precision and execution lane labelled)",
            buckets=obs.LATENCY_BUCKETS)
        # Same family the eager dispatch shims (obs.record_dispatch) feed —
        # the engine's precision downgrade is one more fallback cause, and
        # sharing the family keeps one dashboard query covering both.
        self._m_fallback = reg.counter(
            "solver_fallback_total",
            "solves re-routed off their requested kernel path")
        self._m_retries = reg.counter(
            "solver_retries_total",
            "retry-ladder steps taken, by reason and from/to rung")
        self._m_sweeps = reg.histogram(
            "serve_sweeps",
            "solver sweeps per request (warm label isolates warm-start "
            "savings)", buckets=obs.COUNT_BUCKETS)
        self._m_group = reg.histogram(
            "serve_group_size", "requests per solver call, by batch kind",
            buckets=obs.COUNT_BUCKETS)
        # Bound-series children for the hot label combos: the per-request
        # and per-solve record sites run on the flush path, and rebuilding
        # a sorted label key every call is measurable there (the serve_obs
        # overhead gate holds this under 5%).  Only a handful of combos
        # exist, so the caches stay tiny.
        self._c_served: dict = {}
        self._c_sweeps: dict = {}
        self._c_solve: dict = {}
        self._pending: List[SolveRequest] = []
        # Atomic id source: serve() runs concurrently on lane threads (the
        # async dispatcher), and ``itertools.count`` advances under the GIL
        # so ids never duplicate.
        self._seq = itertools.count()

    def placement_for(self, bucket, method: str) -> Optional[Placement]:
        """Bucket-level placement (None when the engine has no mesh, so
        mesh-less grouping keys stay identical to the pre-placement ones)."""
        if self.mesh is None:
            return None
        return placement_for_bucket(bucket, method, self.policy, self.mesh)

    def spec_for(self, req: SolveRequest, *, record: bool = False
                 ) -> SolverSpec:
        """The effective ``SolverSpec`` a request solves under.

        An explicit ``SolveRequest.spec`` is authoritative; legacy
        per-field requests get the engine-level ``omega``/``ridge``/
        ``precision`` (``ServeConfig``) applied, preserving the pre-spec
        behaviour where those knobs were engine configuration.

        A precision the effective method cannot run (``MethodEntry.
        precisions``) downgrades to "fp32" here — the engine serves the
        request at full precision rather than erroring its whole batch —
        counting ``solver_fallback_total{reason="precision"}``.  The count
        fires only under ``record=True``: ``spec_for`` runs several times
        per request on the flush path (grouping, then each solve body), and
        only the grouping pass (``_flush``'s ``spec_fn``) is once-per-
        request.
        """
        spec = req.solver_spec()
        if req.spec is None:
            spec = spec.replace(omega=self.config.omega,
                                ridge=self.config.ridge)
            if (self.config.precision is not None
                    and spec.precision != self.config.precision):
                spec = spec.replace(precision=self.config.precision)
        # Over-HBM rewrite (store engines): a bucket whose padded X alone
        # exceeds the device byte budget can never be served resident — the
        # store builds it as a non-resident streaming handle — so reroute
        # the BAK-family request to the streaming method up front (same
        # block-Jacobi algorithm; parity-tested against "bakp"), before
        # prefer_fused could upgrade it onto a resident-only path.
        if (self.store is not None and self.store.device_bytes is not None
                and spec.method in _STREAM_REROUTE):
            bucket = request_bucket(req, min_obs=self.config.min_obs,
                                    min_vars=self.config.min_vars)
            if bucket[0] * bucket[1] * 4 > self.store.device_bytes:
                if record:
                    self._m_fallback.inc(1, method=spec.method,
                                         reason="over_hbm")
                spec = spec.replace(method="bakp_stream")
        # The bf16 X stream halves the resident itemsize, so the fit check
        # (and therefore the upgrade) sees twice the VMEM headroom.
        itemsize = 2 if spec.precision != "fp32" else 4
        if (self.config.prefer_fused and spec.method == "bakp"
                and spec.max_iter >= 1):
            if self.mesh is not None:
                # The fused megakernel is single-device; upgrading on a
                # mesh engine would defeat sharded placement, so "bakp"
                # stays — but audibly: the skip counts as a fallback and
                # logs once, instead of the prefer_fused knob silently
                # doing nothing.
                if record:
                    self._m_fallback.inc(1, method="bakp_fused",
                                         reason="unshardable_fused")
                    if not self._warned_unshardable_fused:
                        self._warned_unshardable_fused = True
                        _log.warning(
                            "prefer_fused is a no-op on this mesh engine: "
                            "the fused megakernel is single-device, so "
                            "'bakp' requests keep their sharded-eligible "
                            "method (counted as solver_fallback_total"
                            "{reason=\"unshardable_fused\"})")
            else:
                # Fused eligibility mirrors the method's own dispatch check
                # (nrhs estimated at 1 — the method kernel re-checks with
                # the real coalesced k and falls back when it grew past the
                # budget, so the upgrade is always safe).
                bucket = request_bucket(req, min_obs=self.config.min_obs,
                                        min_vars=self.config.min_vars)
                vars_pb = -(-bucket[1] // spec.thr) * spec.thr
                if fused_fits(vars_pb, bucket[0], 1, itemsize,
                              max_iter=spec.max_iter):
                    spec = spec.replace(method="bakp_fused")
        if (spec.precision != "fp32"
                and spec.precision not in
                solver_method(spec.method).precisions):
            if record:
                self._m_fallback.inc(1, method=spec.method,
                                     reason="precision")
            spec = spec.replace(precision="fp32")
        return spec

    # ------------------------------------------------------------- intake
    def _intake(self, request: SolveRequest) -> str:
        """Normalise one request and assign its id (if absent).

        ``x``/``y``/``a0`` are normalised to host numpy here, once — every
        later ``np.asarray`` in the flush path is then a free view, even
        when the caller handed us device arrays.
        """
        prepare_request(request)
        if request.request_id is None:
            request.request_id = f"req-{next(self._seq)}"
        return request.request_id

    def submit(self, request: SolveRequest) -> str:
        """Queue a request for the next flush(); returns its id.

        submit()/flush() are a single-caller API: the shared pending list
        is deliberately unlocked.  Concurrent callers (the dispatcher's
        lane threads) must use serve(), which never touches it.
        """
        rid = self._intake(request)
        self._pending.append(request)
        return rid

    def serve(self, requests: Sequence[SolveRequest]) -> List[ServedSolve]:
        """Solve ``requests`` in one flush window; results in order.

        Thread-safe: the batch stays local to this call — it never passes
        through the shared submit()/flush() intake — so overlapping
        serve() calls from different lane threads cannot steal each
        other's requests.
        """
        batch = list(requests)
        for r in batch:
            self._intake(r)
        return self._serve(batch)

    # -------------------------------------------------------------- flush
    def flush(self) -> List[ServedSolve]:
        """Execute all pending requests; results in submission order.

        Exception-safe: a solver failure poisons only its own batch — the
        affected requests get error results and every other batch still
        runs, so the returned list always covers all pending requests.
        """
        requests, self._pending = self._pending, []
        return self._serve(requests)

    def _serve(self, requests: List[SolveRequest]) -> List[ServedSolve]:
        if not requests:
            return []
        with self._stats_lock:
            self.stats.requests += len(requests)
        self._m_requests.inc(len(requests))
        with obs.span("engine.flush", requests=len(requests)), \
                obs.profile_region("engine.flush"):
            return self._flush(requests)

    def _flush(self, requests: List[SolveRequest]) -> List[ServedSolve]:
        """Pure batch-builder: grouping, design-cache lookups and lane
        routing happen here on the calling thread; the actual solves are
        work units submitted to the engine's lane pool (``_run_units``), so
        batches bound for different lanes (single-device xla/fused vs each
        mesh placement) overlap instead of serialising."""
        results: List[Optional[ServedSolve]] = [None] * len(requests)
        # (lane, size, run, fail_idxs, bucket) — the last two let
        # _run_units fail a unit's unanswered requests when the unit never
        # ran to completion (lane worker-thread death / shutdown).
        units: List[Tuple[LaneKey, int, object, List[int], tuple]] = []
        cfg = self.config

        def unit(lane, fail_idxs, bucket, size, fn):
            # Exception isolation rides inside the unit: a solver failure
            # poisons only its own batch, exactly as the inline path did.
            def run(fn=fn, fail_idxs=fail_idxs, bucket=bucket):
                try:
                    fn()
                except Exception as exc:
                    self._fail(requests, fail_idxs, bucket, exc, results)
            units.append((lane, size, run, fail_idxs, bucket))

        groups = group_requests(
            requests, min_obs=cfg.min_obs, min_vars=cfg.min_vars,
            placement_fn=self.placement_for,
            # The grouping pass is the once-per-request spec resolution, so
            # it is where a precision downgrade gets counted.
            spec_fn=lambda r: self.spec_for(r, record=True))
        for outer, designs in groups.items():
            bucket = outer[0]
            method = outer[1]
            mentry = solver_method(method)
            placement = self.placement_for(bucket, method)
            singles = []  # (idx, entry, cache_hit, design_key)
            for key, idxs in designs.items():
                try:
                    entry, hit = self._design_entry(key, requests[idxs[0]],
                                                    bucket, placement)
                except Exception as exc:  # bad design: fail just this group
                    self._fail(requests, idxs, bucket, exc, results)
                    continue
                if cfg.coalesce and len(idxs) > 1 and mentry.multi_rhs:
                    # The k-sharded group upgrade is decided here (k is
                    # known after coalescing) so the unit routes to its
                    # real lane, not the bucket's.
                    gplacement = placement
                    if self.mesh is not None and mentry.shardable:
                        gplacement = placement_for_group(
                            placement or Placement(), next_pow2(len(idxs)),
                            self.policy, self.mesh)
                    unit(self.lanes.lane_for(method, gplacement, self.mesh),
                         idxs, bucket, len(idxs),
                         functools.partial(self._solve_multi_rhs, requests,
                                           idxs, entry, hit, bucket,
                                           results, gplacement, key))
                else:
                    singles.extend((i, entry, hit, key) for i in idxs)
            # vmap batching is single-device only (a vmapped shard_map would
            # nest meshes); sharded buckets solve leftovers individually.
            use_vmap = (cfg.vmap_batch and len(singles) > 1
                        and mentry.batchable
                        and (placement is None or not placement.sharded))
            if use_vmap:
                for lo in range(0, len(singles), cfg.max_vmap_batch):
                    chunk = singles[lo:lo + cfg.max_vmap_batch]
                    if len(chunk) > 1:
                        # The vmapped program is a single-device stack —
                        # it rides the method's single-device lane.
                        unit(self.lanes.lane_for(method),
                             [i for i, _, _, _ in chunk], bucket,
                             len(chunk),
                             functools.partial(self._solve_vmapped,
                                               requests, chunk, bucket,
                                               results))
                    else:
                        idx, entry, hit, key = chunk[0]
                        unit(self.lanes.lane_for(method, placement,
                                                 self.mesh),
                             [idx], bucket, 1,
                             functools.partial(self._solve_one, requests,
                                               idx, entry, hit, bucket,
                                               results, placement, key))
            else:
                for idx, entry, hit, key in singles:
                    unit(self.lanes.lane_for(method, placement, self.mesh),
                         [idx], bucket, 1,
                         functools.partial(self._solve_one, requests, idx,
                                           entry, hit, bucket, results,
                                           placement, key))
        self._run_units(units, requests, results)
        assert all(r is not None for r in results)
        return results

    def _run_units(self, units, requests, results) -> None:
        """Execute flush work units on their lanes and wait for all.

        Nested flushes (``serve``/``flush`` called from a lane work — the
        dispatcher's per-batch submission path) run inline on the current
        lane thread: the batch was already routed to its lane, and
        re-submitting from inside a lane could deadlock a lane on itself.

        Units swallow solver errors via ``_fail``; a work coming back with
        ``error`` set means the unit never completed — lane worker-thread
        death (``LaneWorkerDeath``) or a shutdown race.  Its unanswered
        requests get error results and the flush still returns a full
        result list: the engine keeps serving through a dying lane.
        """
        if not units:
            return
        if current_lane() is not None:
            for _, _, fn, _, _ in units:
                fn()
            return
        works = [self.lanes.submit(lane, LaneWork(fn, size=size,
                                                  tag=lane.label))
                 for lane, size, fn, _, _ in units]
        for w in works:
            w.wait()
        for w, (_, _, _, fail_idxs, bucket) in zip(works, units):
            if w.error is not None:
                missing = [i for i in fail_idxs if results[i] is None]
                if missing:
                    self._fail(requests, missing, bucket, w.error, results)

    def shutdown(self, drain: bool = True) -> None:
        """Stop the engine's lane executor threads (idempotent; the engine
        keeps working afterwards only via fresh lane threads on the next
        flush, so call this at teardown)."""
        self.lanes.shutdown(drain=drain)

    # ---------------------------------------------------------- internals
    def _design_entry(self, key, req, bucket, placement=None):
        return self.cache.get_or_build(
            key, lambda: pad_x(np.asarray(req.x), bucket),
            placement=placement, mesh=self.mesh)

    def _fail(self, requests, idxs, bucket, exc, results):
        """Error results for a poisoned batch (engine keeps serving).

        Failures are structured, not just stringly: each request bumps
        ``serve_errors_total{exception_type,method,bucket}`` and carries a
        telemetry record naming the failing bucket/method, so a poisoned
        batch is diagnosable from a metrics scrape alone.
        """
        exc_type = type(exc).__name__
        msg = f"{exc_type}: {exc}"
        obs.consume_dispatch()  # drop any path a partial dispatch recorded
        for idx in idxs:
            req = requests[idx]
            n_obs, nvars = np.asarray(req.x).shape
            tel = None
            if obs.enabled():
                tel = obs.SolveTelemetry(
                    request_id=req.request_id, tenant_id=req.tenant_id,
                    bucket=bucket, method=req.method, kernel_path="none",
                    batch_kind="error", group_size=len(idxs),
                    batch_size=len(idxs), error_type=exc_type)
            results[idx] = ServedSolve(
                request_id=req.request_id,
                coef=np.zeros((nvars,), np.float32),
                residual=np.asarray(req.y, np.float32).copy(),
                sse=float(np.dot(req.y, req.y)),
                n_sweeps=0,
                converged=False,
                bucket=bucket,
                batch_kind="error",
                group_size=len(idxs),
                error=msg,
                telemetry=tel,
            )
            with self._stats_lock:
                self.stats.failures += 1
            self._m_errors.inc(1, exception_type=exc_type,
                               method=req.method,
                               bucket=f"{bucket[0]}x{bucket[1]}")

    def _resolve_a0(self, req: SolveRequest, entry: PreparedDesign):
        """Warm-start coefficients for a request: explicit ``a0`` wins,
        then the design handle's per-tenant store; None means cold."""
        if req.a0 is not None:
            return np.asarray(req.a0, np.float32)
        if self.config.warm_cache:
            return entry.warm_coef(req.tenant_id)
        return None

    @staticmethod
    def _pad_a0(a0: np.ndarray, vars_p: int) -> np.ndarray:
        """Zero-pad (vars,) warm-start coefficients to the bucket width.

        Zero entries for padded columns are exact: those columns are zero,
        so their coefficients stay pinned at 0 either way.
        """
        if a0.shape[0] == vars_p:
            return a0
        out = np.zeros((vars_p,), np.float32)
        out[: a0.shape[0]] = a0
        return out

    @staticmethod
    def _padded_atol(atol: float, n_real: int, n_padded: int) -> float:
        """Correct an absolute RMSE tolerance for zero padding.

        The solvers compare total SSE against ``n_padded * atol²``, but only
        ``n_real`` of those elements carry signal (padding rows/RHS hold
        exactly zero residual), so the raw threshold would be inflated by
        n_padded/n_real.  Scaling atol by sqrt(n_real/n_padded) makes the
        padded criterion equal the unpadded one.  ``rtol`` needs no
        correction (padding contributes 0 to both sides of the ratio).
        """
        if atol <= 0.0 or n_real == n_padded:
            return atol
        return atol * math.sqrt(n_real / n_padded)

    def _call_solver(self, spec: SolverSpec, entry: PreparedDesign, y_dev,
                     atol: float, a0=None, placement=None):
        """One (possibly multi-RHS) solve on the prepared design.

        Everything dispatches through ``PreparedDesign.solve`` — the
        engine's only job here is the serving-side corrections: ``atol`` is
        the padding-corrected absolute tolerance (see ``_padded_atol``;
        ``spec.atol`` itself must not be used), and a 2-D mesh placement
        gets the engine's ``omega_2d`` damping (its cross-device Jacobi
        block is D·thr wide).  ``a0`` is the bucket-padded warm start (or
        None for the cold program — a separate jit signature, so cold
        solves don't pay the warm path's extra residual matmul).
        """
        eff = spec.replace(atol=atol)
        if placement is not None and placement.kind == "mesh_2d":
            eff = eff.replace(omega=self.config.omega_2d)
        with obs.profile_region(f"solve/{eff.method}"):
            return entry.solve(y_dev, a0, spec=eff, placement=placement,
                               mesh=self.mesh)

    # ------------------------------------------------------- retry ladder
    @staticmethod
    def _rung_label(spec: SolverSpec, warm: bool = False) -> str:
        """Metrics label for one ladder rung: method, ':<precision>' when
        reduced, '+warm' when warm-started."""
        lbl = spec.method
        if spec.precision != "fp32":
            lbl += f":{spec.precision}"
        if warm:
            lbl += "+warm"
        return lbl

    @staticmethod
    def _diverged(res, sse0: Optional[float] = None) -> bool:
        """Whether a completed solve net-diverged (see
        ``core.types.warm_retention_ok`` for the history semantics): not
        converged AND the recorded SSE rose materially above its own start
        — or above the caller-supplied cold baseline ``sse0`` (= |y|², the
        SSE of the zero solution), which catches a warm start that blew up
        from its very first sweep."""
        try:
            conv = np.asarray(res.converged)
            if conv.ndim != 0 or bool(conv):
                return False
            h = np.asarray(res.history, np.float32).ravel()
            h = h[np.isfinite(h)]
            if h.size == 0:
                return False
            if h.size >= 2 and float(h[-1]) > 1.01 * float(h[0]):
                return True
            if sse0 is not None and float(h[-1]) > 1.01 * sse0:
                return True
        except Exception:
            return False
        return False

    @staticmethod
    def _is_corruption(exc: BaseException) -> bool:
        """Did this solve die because the design's store tier is damaged?
        (Quarantine already happened inside the store; the ladder's job is
        to rebuild the entry from the request's ``x`` and retry.)"""
        if isinstance(exc, TileCorruptionError):
            return True
        return isinstance(exc, KeyError) and "store tier" in str(exc)

    def _rung_ok(self, spec: SolverSpec, entry, need_multi: bool) -> bool:
        """Can this entry/batch actually run on the given rung?"""
        m = solver_method(spec.method)
        if entry.x_pad is None and not m.streams:
            return False  # non-resident design: streaming rungs only
        if need_multi and not m.multi_rhs:
            return False  # coalesced (obs, k) batch stays coalesced
        return True

    def _attempt_solve(self, spec: SolverSpec, entry, y, atol: float, a0,
                       placement, *, deadline_at: Optional[float] = None,
                       rebuild=None, sse0: Optional[float] = None,
                       need_multi: bool = False):
        """One solve with the retry/degradation ladder wrapped around it.

        Runs ``_call_solver`` and retries on a raised exception or a
        *diverged* result, stepping down a capability-aware ladder:

          1. store corruption → rebuild the design entry from the request's
             ``x`` (``rebuild``) and retry the SAME rung;
          2. warm start present → cold retry on the same rung (a poisoned
             ``a0`` is the usual suspect);
          3. reduced precision → fp32, same method;
          4. ``MethodEntry.fallback`` hops (fused → persweep → stream →
             lstsq), skipping rungs the entry/batch cannot run
             (``_rung_ok``); a method change drops the mesh placement (the
             fallback method may not be shardable).

        Bounded by ``ServeConfig.max_retries``, the request deadline
        (``deadline_at``, obs.now() clock) and the ladder floor; each step
        sleeps a jittered exponential backoff and counts
        ``solver_retries_total{reason,from_path,to_path}``.  When the
        ladder is exhausted the last exception re-raises (→ ``_fail``) or
        the last diverged result returns as-is (flagged so ``_strip``
        skips warm retention).

        Returns ``(res, spec, entry, placement, retries, diverged,
        a0_used)`` — the rung that finally served, so the caller records
        the method/path that actually ran.
        """
        cfg = self.config
        cur, cur_entry, cur_a0, cur_place = spec, entry, a0, placement
        retries = 0
        while True:
            exc = None
            res = None
            try:
                faults.maybe_raise("solver.raise", cur.method)
                res = self._call_solver(cur, cur_entry, y, atol, a0=cur_a0,
                                        placement=cur_place)
                jax.block_until_ready(res.coef)
            except Exception as e:
                exc = e
            forced = (exc is None
                      and faults.hit("solver.diverge", cur.method)
                      is not None)
            diverged = forced or (exc is None and self._diverged(res, sse0))
            if exc is None and not diverged:
                return (res, cur, cur_entry, cur_place, retries, False,
                        cur_a0)
            out_of_time = (deadline_at is not None
                           and obs.now() >= deadline_at)
            if (not cfg.retry_ladder or retries >= cfg.max_retries
                    or out_of_time):
                if exc is not None:
                    raise exc
                return (res, cur, cur_entry, cur_place, retries, True,
                        cur_a0)
            # Pick the next rung (the first applicable recovery, in order).
            frm = self._rung_label(cur, cur_a0 is not None)
            if (exc is not None and self._is_corruption(exc)
                    and rebuild is not None):
                reason, nxt = "corruption", cur
                try:
                    cur_entry = rebuild()
                except Exception:
                    raise exc  # design is gone for good — report the solve
            elif cur_a0 is not None:
                reason, nxt, cur_a0 = "warm_poison", cur, None
            else:
                reason = "raise" if exc is not None else (
                    "forced_diverge" if forced else "diverge")
                nxt = ladder.next_rung(cur)
                while nxt is not None and not self._rung_ok(
                        nxt, cur_entry, need_multi):
                    nxt = ladder.next_rung(nxt)
                if nxt is None:  # ladder floor reached
                    if exc is not None:
                        raise exc
                    return (res, cur, cur_entry, cur_place, retries, True,
                            cur_a0)
                if nxt.method != cur.method:
                    cur_place = None  # fallback may not be shardable
            retries += 1
            self._m_retries.inc(1, reason=reason, from_path=frm,
                                to_path=self._rung_label(
                                    nxt, cur_a0 is not None))
            with self._stats_lock:
                self.stats.retries += 1
            delay = ladder.backoff_s(retries - 1, cfg.retry_backoff_s)
            if delay > 0.0:
                if deadline_at is not None:
                    delay = min(delay, max(0.0, deadline_at - obs.now()))
                time.sleep(delay)
            cur = nxt

    def _record_solve(self, spec: SolverSpec, placement, kind: str,
                      group_size: int, dt: float, path=None) -> str:
        """Record one solver call's metrics; returns the kernel path that
        actually executed.

        The path comes off the thread-local relay the eager dispatch shims
        filled (``obs.record_dispatch`` in ``repro.core.methods`` /
        ``repro.kernels.ops``) — a ``bakp_fused`` request that outgrew VMEM
        reports "xla" here, not what the spec asked for.  ``path`` forces
        it where the engine knows better (the vmapped batch program).
        """
        if path is None:
            path = obs.consume_dispatch(
                "sharded" if placement is not None and placement.sharded
                else "xla")
        if obs.enabled():
            placement_kind = (placement.kind if placement is not None
                              else "single")
            lk = current_lane()
            lane = lk.label if lk is not None else "inline"
            ck = (kind, spec.method, path, placement_kind, spec.precision,
                  lane)
            bound = self._c_solve.get(ck)
            if bound is None:
                bound = self._c_solve[ck] = (
                    self._m_solves.labels(kind=kind, method=spec.method,
                                          path=path,
                                          placement=placement_kind),
                    self._m_latency.labels(kind=kind, method=spec.method,
                                           path=path,
                                           precision=spec.precision,
                                           lane=lane),
                    self._m_group.labels(kind=kind))
            bound[0].inc(1)
            bound[1].observe(dt)
            bound[2].observe(group_size)
        return path

    def _strip(self, req: SolveRequest, coef, residual, *, bucket, kind,
               group_size, latency, hit, n_sweeps, converged, entry=None,
               warm=False, placement=None, method="", path="xla",
               retain_warm=True, retries=0) -> ServedSolve:
        n_obs, nvars = np.asarray(req.x).shape
        coef = np.asarray(coef)[:nvars]
        residual = np.asarray(residual)[:n_obs]
        # ``retain_warm=False`` = the solve diverged: its coefficients are
        # worse than zero, and retaining them would poison the tenant's
        # next warm start into starting from the blown-up point.
        if entry is not None and self.config.warm_cache and retain_warm:
            entry.store_coef(req.tenant_id, coef)
        if warm:
            with self._stats_lock:
                self.stats.warm_starts += 1
        sse = float(np.dot(residual, residual))
        n_sweeps = int(n_sweeps)
        converged = bool(converged)
        placement_kind = placement.kind if placement is not None else "single"
        lk = current_lane()
        lane = lk.label if lk is not None else "inline"
        tel = None
        if obs.enabled():
            warm_lbl = "1" if warm else "0"
            sk = (kind, warm_lbl)
            served_c = self._c_served.get(sk)
            if served_c is None:
                served_c = self._c_served[sk] = self._m_served.labels(
                    kind=kind, warm=warm_lbl)
            sweeps_c = self._c_sweeps.get(warm_lbl)
            if sweeps_c is None:
                sweeps_c = self._c_sweeps[warm_lbl] = self._m_sweeps.labels(
                    warm=warm_lbl)
            served_c.inc(1)
            sweeps_c.observe(n_sweeps)
            tel = obs.SolveTelemetry(
                request_id=req.request_id, tenant_id=req.tenant_id,
                bucket=bucket, method=method or req.method,
                kernel_path=path, placement=placement_kind, lane=lane,
                batch_kind=kind,
                group_size=group_size, batch_size=group_size,
                warm_start=warm, cache_hit=hit, n_sweeps=n_sweeps, sse=sse,
                converged=converged, retries=retries, solve_s=latency)
        return ServedSolve(
            request_id=req.request_id,
            coef=coef,
            residual=residual,
            sse=sse,
            n_sweeps=n_sweeps,
            converged=converged,
            bucket=bucket,
            batch_kind=kind,
            group_size=group_size,
            latency_s=latency,
            cache_hit=hit,
            warm_start=warm,
            placement=placement_kind,
            retries=retries,
            telemetry=tel,
        )

    def _solve_multi_rhs(self, requests, idxs, entry, hit, bucket, results,
                         placement=None, key=None):
        """Coalesce same-design requests into one (obs, k_pad) solve.

        Warm and cold members coalesce: if any member warm-starts, the
        group solve gets a stacked ``a0`` whose cold columns are zero
        (identical to those members' cold path).

        ``placement`` is final here — the k-sharded group upgrade (one
        stream of ``x`` per device serves k/D tenants, group-global SSE
        stopping) is decided by ``_flush`` at unit-build time, where the
        lane is chosen — except that the retry ladder drops it when a
        fallback rung changes the method (see ``_attempt_solve``).
        """
        obs_p, vars_p = bucket
        k = len(idxs)
        k_pad = next_pow2(k)
        req0 = requests[idxs[0]]
        spec = self.spec_for(req0)
        mentry = solver_method(spec.method)
        ys = np.zeros((obs_p, k_pad), np.float32)
        sse0 = 0.0
        for c, idx in enumerate(idxs):
            y = np.asarray(requests[idx].y, np.float32)
            ys[: y.shape[0], c] = y
            sse0 += float(np.dot(y, y))
        if mentry.iterative:
            a0s = [self._resolve_a0(requests[idx], entry) for idx in idxs]
        else:  # direct methods don't iterate, so warm starts are meaningless
            a0s = [None] * k
        a0_mat = None
        if any(a is not None for a in a0s):
            a0_mat = np.zeros((vars_p, k_pad), np.float32)
            for c, a in enumerate(a0s):
                if a is not None:
                    a0_mat[:, c] = self._pad_a0(a, vars_p)
        # Same design => same real obs for every member of the group.
        obs_real = np.asarray(req0.x).shape[0]
        atol = self._padded_atol(spec.atol, obs_real * k, obs_p * k_pad)
        deadlines = [requests[i].deadline_at for i in idxs
                     if requests[i].deadline_at is not None]
        rebuild = None
        if key is not None:
            rebuild = lambda: self._design_entry(  # noqa: E731
                key, req0, bucket, placement)[0]
        t0 = obs.now()
        # ys/a0_mat go in as HOST buffers: the solver entries donate their
        # fresh in-jit transfers on accelerator backends (the steady-state
        # HBM saving of the flush path — see types.donate_default).
        res, fspec, fentry, fplace, retries, diverged, a0_used = \
            self._attempt_solve(
                spec, entry, ys, atol, a0_mat, placement,
                deadline_at=min(deadlines) if deadlines else None,
                rebuild=rebuild, sse0=sse0, need_multi=True)
        dt = obs.now() - t0
        path = self._record_solve(fspec, fplace, "multi_rhs", k, dt)
        coef = np.asarray(res.coef)
        resid = np.asarray(res.residual)
        for c, idx in enumerate(idxs):
            results[idx] = self._strip(
                requests[idx], coef[:, c], resid[:, c], bucket=bucket,
                kind="multi_rhs", group_size=k, latency=dt, hit=hit,
                n_sweeps=res.n_sweeps, converged=res.converged,
                entry=fentry,
                warm=a0_used is not None and a0s[c] is not None,
                placement=fplace, method=fspec.method, path=path,
                retain_warm=not diverged, retries=retries)
        with self._stats_lock:
            self.stats.solver_calls += 1
            self.stats.multi_rhs_groups += 1
            self.stats.multi_rhs_requests += k
            if fplace is not None and fplace.sharded:
                self.stats.sharded_solves += 1

    def _solve_vmapped(self, requests, singles, bucket, results):
        """Stack same-bucket single-design requests into one vmapped solve.

        Degradation (retry ladder): a raised vmapped batch is not retried
        as a stack — there is no batched ladder — it degrades to
        per-request ``_solve_one`` calls, each with its own full ladder;
        a member whose own ladder also exhausts fails alone.  Counted as
        ``solver_retries_total{reason=...,from_path="vmap:...",
        to_path="single"}`` once per member.
        """
        try:
            self._solve_vmapped_inner(requests, singles, bucket, results)
            return
        except Exception as exc:
            if not self.config.retry_ladder:
                raise
            spec = self.spec_for(requests[singles[0][0]])
            reason = ("raise" if isinstance(exc, faults.FaultInjected)
                      else type(exc).__name__)
            self._m_retries.inc(len(singles), reason=reason,
                                from_path=f"vmap:{spec.method}",
                                to_path="single")
            with self._stats_lock:
                self.stats.retries += len(singles)
        for idx, entry, hit, key in singles:
            if results[idx] is not None:
                continue
            try:
                self._solve_one(requests, idx, entry, hit, bucket, results,
                                None, key)
            except Exception as exc:
                self._fail(requests, [idx], bucket, exc, results)

    def _solve_vmapped_inner(self, requests, singles, bucket, results):
        obs_p, vars_p = bucket
        req0 = requests[singles[0][0]]
        spec = self.spec_for(req0)
        mentry = solver_method(spec.method)
        b = len(singles)
        b_pad = next_pow2(b)
        # Pad the batch by replicating the last system (discarded below) so
        # the vmapped program only ever compiles for power-of-two batches.
        padded = singles + [singles[-1]] * (b_pad - b)
        xs = jnp.stack([entry.x_pad for _, entry, _, _ in padded])
        ys = jnp.asarray(np.stack(
            [pad_y(np.asarray(requests[i].y, np.float32), obs_p)
             for i, _, _, _ in padded]))
        a0s = [self._resolve_a0(requests[i], e) for i, e, _, _ in padded]
        warm = any(a is not None for a in a0s)
        solver = _vmapped_solver(spec.canonical().replace(atol=0.0), warm)
        # Per-element padding-corrected atol (real obs varies within a
        # bucket); traced, so it never forces a recompile.
        atols = jnp.asarray([
            self._padded_atol(spec.atol, np.asarray(requests[i].x).shape[0],
                              obs_p)
            for i, _, _, _ in padded], dtype=jnp.float32)
        if mentry.blocked:
            cns = jnp.stack(
                [e.cn_for_thr(spec.thr) for _, e, _, _ in padded])
        else:
            cns = jnp.stack([e.cn for _, e, _, _ in padded])
        if mentry.needs_chol:
            chols = jnp.stack(
                [e.chol_for(spec.thr, spec.ridge) for _, e, _, _ in padded])
            args = (xs, ys, cns, atols, chols)
        else:
            args = (xs, ys, cns, atols)
        if warm:
            a0_mat = np.zeros((b_pad, vars_p), np.float32)
            for row, a in enumerate(a0s):
                if a is not None:
                    a0_mat[row] = self._pad_a0(a, vars_p)
            args = args + (jnp.asarray(a0_mat),)
        t0 = obs.now()
        faults.maybe_raise("solver.raise", f"vmap:{spec.method}")
        with obs.profile_region(f"solve/vmap/{spec.method}"):
            res = solver(*args)
            jax.block_until_ready(res.coef)
        dt = obs.now() - t0
        forced = faults.hit("solver.diverge", f"vmap:{spec.method}")
        # The vmapped program is one jit'd stack — the eager dispatch shims
        # never run inside it, so the path is "vmap" by construction.
        obs.consume_dispatch()
        path = self._record_solve(spec, None, "vmap", b, dt, path="vmap")
        coef = np.asarray(res.coef)
        resid = np.asarray(res.residual)
        conv_b = np.asarray(res.converged)
        hist_b = np.asarray(res.history, np.float32)

        def row_retain(row: int) -> bool:
            # Per-row warm retention: the batched analogue of
            # core.types.warm_retention_ok (which is scalar-only).
            if forced is not None:
                return False
            if bool(conv_b[row]):
                return True
            h = hist_b[row][np.isfinite(hist_b[row])]
            return not (h.size >= 2 and float(h[-1]) > 1.01 * float(h[0]))

        for row, (idx, entry, hit, _) in enumerate(singles):
            results[idx] = self._strip(
                requests[idx], coef[row], resid[row], bucket=bucket,
                kind="vmap", group_size=b, latency=dt, hit=hit,
                n_sweeps=res.n_sweeps[row], converged=res.converged[row],
                entry=entry, warm=a0s[row] is not None,
                method=spec.method, path=path,
                retain_warm=row_retain(row))
        with self._stats_lock:
            self.stats.solver_calls += 1
            self.stats.vmap_batches += 1
            self.stats.vmap_requests += b

    def _solve_one(self, requests, idx, entry, hit, bucket, results,
                   placement=None, key=None):
        req = requests[idx]
        spec = self.spec_for(req)
        y_real = np.asarray(req.y, np.float32)
        y_pad = pad_y(y_real, bucket[0])
        atol = self._padded_atol(spec.atol, y_real.shape[0], bucket[0])
        a0 = None
        if solver_method(spec.method).iterative:
            a0 = self._resolve_a0(req, entry)
        a0_pad = None
        if a0 is not None:
            a0_pad = self._pad_a0(a0, bucket[1])
        rebuild = None
        if key is not None:
            rebuild = lambda: self._design_entry(  # noqa: E731
                key, req, bucket, placement)[0]
        t0 = obs.now()
        # Host buffers in — see _solve_multi_rhs on donation.
        res, fspec, fentry, fplace, retries, diverged, a0_used = \
            self._attempt_solve(spec, entry, y_pad, atol, a0_pad, placement,
                                deadline_at=req.deadline_at,
                                rebuild=rebuild,
                                sse0=float(np.dot(y_real, y_real)))
        dt = obs.now() - t0
        path = self._record_solve(fspec, fplace, "single", 1, dt)
        results[idx] = self._strip(
            req, res.coef, res.residual, bucket=bucket, kind="single",
            group_size=1, latency=dt, hit=hit, n_sweeps=res.n_sweeps,
            converged=res.converged, entry=fentry,
            warm=a0_used is not None, placement=fplace,
            method=fspec.method, path=path, retain_warm=not diverged,
            retries=retries)
        with self._stats_lock:
            self.stats.solver_calls += 1
            self.stats.single_solves += 1
            if fplace is not None and fplace.sharded:
                self.stats.sharded_solves += 1
