"""Batched multi-tenant solver-serving engine.

``SolverServeEngine`` turns a stream of per-tenant ``SolveRequest``s into a
small number of compiled batch solves:

  1. **Bucketing** — requests are grouped by padded power-of-two shape (and
     solver config), so the jit compile cache is bounded by the number of
     buckets seen, not the number of distinct request shapes.
  2. **Same-design coalescing** — requests whose design matrix fingerprints
     match are merged into ONE multi-RHS solve: ``y`` becomes (obs, k) and a
     single stream of ``x`` (the solver's entire memory traffic) serves all
     k tenants.  k is itself padded to a power of two to bound recompiles.
  3. **Same-bucket vmap batching** — leftover single-design requests in a
     bucket are stacked and solved with one vmapped call (batch padded to a
     power of two by replicating the last system; replicas are discarded).
  4. **Design caching** — everything that depends only on ``x`` (device
     copy, column norms, block-Gram Cholesky factors) is memoised across
     flushes in an LRU ``DesignCache``.

Results come back as per-request ``ServedSolve``s, in submission order, with
padding stripped and per-request SSE recomputed from the stripped residual.

Example::

    engine = SolverServeEngine()
    for x, y in workload:
        engine.submit(SolveRequest(x=x, y=y, method="bakp_gram", rtol=1e-8))
    for served in engine.flush():
        use(served.coef)
"""
from __future__ import annotations

import functools
import math
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import _METHODS, solve
from repro.core.solvebak import solvebak
from repro.core.solvebakp import solvebakp
from repro.serve.batching import group_requests, next_pow2, pad_x, pad_y
from repro.serve.cache import DesignCache, DesignEntry
from repro.serve.types import ServedSolve, SolveRequest

# Methods that can be vmap-batched across designs.  Same-design multi-RHS
# coalescing applies to every method (all of them accept y of shape (obs, k)).
_BATCHABLE = ("bak", "bakp", "bakp_gram")


@dataclass
class ServeConfig:
    """Engine-level knobs (per-request solver knobs live on SolveRequest)."""

    omega: float = 1.0
    ridge: float = 1e-6
    min_obs: int = 8
    min_vars: int = 8
    coalesce: bool = True        # same-design requests → one multi-RHS solve
    vmap_batch: bool = True      # same-bucket singles → one vmapped solve
    max_vmap_batch: int = 64     # cap on vmapped batch size (memory bound)
    cache_entries: int = 64      # LRU design-cache capacity


@dataclass
class ServeStats:
    requests: int = 0
    solver_calls: int = 0
    multi_rhs_groups: int = 0
    multi_rhs_requests: int = 0
    vmap_batches: int = 0
    vmap_requests: int = 0
    single_solves: int = 0


@functools.lru_cache(maxsize=32)
def _vmapped_solver(method: str, max_iter: int, rtol: float, thr: int,
                    omega: float, ridge: float):
    """jit(vmap(...)) batch solver for one static solver config.

    Module-level lru_cache keeps the function object (and therefore the jit
    compile cache) stable across engine instances and flushes; the bounded
    maxsize caps memory when tenants send many distinct knob combinations
    (evicting the wrapper releases its jit executables).  ``atol`` is a
    *traced per-element* argument (not part of the cache key): requests in
    one bucket can have different real obs, so each gets its own
    padding-corrected absolute tolerance without recompiling.
    """
    if method == "bak":
        def one(x, y, cn, atol):
            return solvebak(x, y, max_iter=max_iter, atol=atol, rtol=rtol,
                            cn=cn)
    elif method == "bakp":
        def one(x, y, cn, atol):
            return solvebakp(x, y, thr=thr, max_iter=max_iter, atol=atol,
                             rtol=rtol, omega=omega, mode="jacobi", cn=cn)
    elif method == "bakp_gram":
        def one(x, y, cn, atol, chol):
            return solvebakp(x, y, thr=thr, max_iter=max_iter, atol=atol,
                             rtol=rtol, omega=omega, mode="gram", ridge=ridge,
                             cn=cn, chol=chol)
    else:
        raise ValueError(f"method {method!r} is not vmap-batchable")
    return jax.jit(jax.vmap(one))


class SolverServeEngine:
    """Multi-tenant batched serving front-end for the BAK solver family."""

    def __init__(self, config: Optional[ServeConfig] = None):
        self.config = config or ServeConfig()
        self.cache = DesignCache(max_entries=self.config.cache_entries)
        self.stats = ServeStats()
        self._pending: List[SolveRequest] = []
        self._seq = 0

    # ------------------------------------------------------------- intake
    def submit(self, request: SolveRequest) -> str:
        """Queue a request; returns its (possibly auto-assigned) id.

        ``x``/``y`` are normalised to host numpy here, once — every later
        ``np.asarray`` in the flush path is then a free view, even when the
        caller handed us device arrays.
        """
        x = request.x = np.asarray(request.x)
        if x.ndim != 2:
            raise ValueError(f"request x must be 2D (obs, vars), got {x.shape}")
        y = request.y = np.asarray(request.y)
        if y.ndim != 1 or y.shape[0] != x.shape[0]:
            raise ValueError(
                f"request y must be (obs,) matching x rows, got {y.shape} "
                f"for x {x.shape}")
        if request.method not in _METHODS:
            raise ValueError(
                f"method must be one of {_METHODS}, got {request.method!r}")
        if request.request_id is None:
            request.request_id = f"req-{self._seq}"
        self._seq += 1
        self._pending.append(request)
        return request.request_id

    def serve(self, requests: Sequence[SolveRequest]) -> List[ServedSolve]:
        """submit() every request, then flush()."""
        for r in requests:
            self.submit(r)
        return self.flush()

    # -------------------------------------------------------------- flush
    def flush(self) -> List[ServedSolve]:
        """Execute all pending requests; results in submission order."""
        requests, self._pending = self._pending, []
        if not requests:
            return []
        self.stats.requests += len(requests)
        results: List[Optional[ServedSolve]] = [None] * len(requests)
        cfg = self.config
        groups = group_requests(requests, min_obs=cfg.min_obs,
                                min_vars=cfg.min_vars)
        for outer, designs in groups.items():
            bucket = outer[0]
            method = outer[1]
            singles = []  # (idx, entry, cache_hit)
            for key, idxs in designs.items():
                entry, hit = self._design_entry(key, requests[idxs[0]], bucket)
                if cfg.coalesce and len(idxs) > 1:
                    self._solve_multi_rhs(requests, idxs, entry, hit, bucket,
                                          results)
                else:
                    singles.extend((i, entry, hit) for i in idxs)
            if cfg.vmap_batch and len(singles) > 1 and method in _BATCHABLE:
                for lo in range(0, len(singles), cfg.max_vmap_batch):
                    chunk = singles[lo:lo + cfg.max_vmap_batch]
                    if len(chunk) > 1:
                        self._solve_vmapped(requests, chunk, bucket, results)
                    else:
                        self._solve_one(requests, *chunk[0], bucket, results)
            else:
                for idx, entry, hit in singles:
                    self._solve_one(requests, idx, entry, hit, bucket, results)
        assert all(r is not None for r in results)
        return results

    # ---------------------------------------------------------- internals
    def _design_entry(self, key, req, bucket):
        return self.cache.get_or_build(
            key, lambda: pad_x(np.asarray(req.x), bucket))

    @staticmethod
    def _padded_atol(atol: float, n_real: int, n_padded: int) -> float:
        """Correct an absolute RMSE tolerance for zero padding.

        The solvers compare total SSE against ``n_padded * atol²``, but only
        ``n_real`` of those elements carry signal (padding rows/RHS hold
        exactly zero residual), so the raw threshold would be inflated by
        n_padded/n_real.  Scaling atol by sqrt(n_real/n_padded) makes the
        padded criterion equal the unpadded one.  ``rtol`` needs no
        correction (padding contributes 0 to both sides of the ratio).
        """
        if atol <= 0.0 or n_real == n_padded:
            return atol
        return atol * math.sqrt(n_real / n_padded)

    def _call_solver(self, req: SolveRequest, entry: DesignEntry, y_dev,
                     atol: float):
        """One (possibly multi-RHS) solve on the padded design.

        ``atol`` is the padding-corrected absolute tolerance (see
        ``_padded_atol``); ``req.atol`` itself must not be used here.
        """
        cfg = self.config
        m = req.method
        if m == "bak":
            return solvebak(entry.x_pad, y_dev, max_iter=req.max_iter,
                            atol=atol, rtol=req.rtol, cn=entry.cn)
        if m == "bakp":
            return solvebakp(entry.x_pad, y_dev, thr=req.thr,
                             max_iter=req.max_iter, atol=atol,
                             rtol=req.rtol, omega=cfg.omega, mode="jacobi",
                             cn=entry.cn_for_thr(req.thr))
        if m == "bakp_gram":
            return solvebakp(entry.x_pad, y_dev, thr=req.thr,
                             max_iter=req.max_iter, atol=atol,
                             rtol=req.rtol, omega=cfg.omega, mode="gram",
                             ridge=cfg.ridge, cn=entry.cn_for_thr(req.thr),
                             chol=entry.chol_for(req.thr, cfg.ridge))
        # Direct baselines ride the cached padded design but not cn/chol
        # (atol is an iteration knob; direct methods don't use it).
        return solve(entry.x_pad, y_dev, method=m, max_iter=req.max_iter)

    def _strip(self, req: SolveRequest, coef, residual, *, bucket, kind,
               group_size, latency, hit, n_sweeps, converged) -> ServedSolve:
        obs, nvars = np.asarray(req.x).shape
        coef = np.asarray(coef)[:nvars]
        residual = np.asarray(residual)[:obs]
        return ServedSolve(
            request_id=req.request_id,
            coef=coef,
            residual=residual,
            sse=float(np.dot(residual, residual)),
            n_sweeps=int(n_sweeps),
            converged=bool(converged),
            bucket=bucket,
            batch_kind=kind,
            group_size=group_size,
            latency_s=latency,
            cache_hit=hit,
        )

    def _solve_multi_rhs(self, requests, idxs, entry, hit, bucket, results):
        """Coalesce same-design requests into one (obs, k_pad) solve."""
        obs_p = bucket[0]
        k = len(idxs)
        k_pad = next_pow2(k)
        ys = np.zeros((obs_p, k_pad), np.float32)
        for c, idx in enumerate(idxs):
            y = np.asarray(requests[idx].y, np.float32)
            ys[: y.shape[0], c] = y
        req0 = requests[idxs[0]]
        # Same design => same real obs for every member of the group.
        obs_real = np.asarray(req0.x).shape[0]
        atol = self._padded_atol(req0.atol, obs_real * k, obs_p * k_pad)
        t0 = time.perf_counter()
        res = self._call_solver(req0, entry, jnp.asarray(ys), atol)
        jax.block_until_ready(res.coef)
        dt = time.perf_counter() - t0
        coef = np.asarray(res.coef)
        resid = np.asarray(res.residual)
        for c, idx in enumerate(idxs):
            results[idx] = self._strip(
                requests[idx], coef[:, c], resid[:, c], bucket=bucket,
                kind="multi_rhs", group_size=k, latency=dt, hit=hit,
                n_sweeps=res.n_sweeps, converged=res.converged)
        self.stats.solver_calls += 1
        self.stats.multi_rhs_groups += 1
        self.stats.multi_rhs_requests += k

    def _solve_vmapped(self, requests, singles, bucket, results):
        """Stack same-bucket single-design requests into one vmapped solve."""
        obs_p = bucket[0]
        req0 = requests[singles[0][0]]
        b = len(singles)
        b_pad = next_pow2(b)
        # Pad the batch by replicating the last system (discarded below) so
        # the vmapped program only ever compiles for power-of-two batches.
        padded = singles + [singles[-1]] * (b_pad - b)
        xs = jnp.stack([entry.x_pad for _, entry, _ in padded])
        ys = jnp.asarray(np.stack(
            [pad_y(np.asarray(requests[i].y, np.float32), obs_p)
             for i, _, _ in padded]))
        m = req0.method
        solver = _vmapped_solver(m, req0.max_iter, float(req0.rtol),
                                 int(req0.thr), float(self.config.omega),
                                 float(self.config.ridge))
        # Per-element padding-corrected atol (real obs varies within a
        # bucket); traced, so it never forces a recompile.
        atols = jnp.asarray([
            self._padded_atol(req0.atol, np.asarray(requests[i].x).shape[0],
                              obs_p)
            for i, _, _ in padded], dtype=jnp.float32)
        if m == "bakp_gram":
            cns = jnp.stack([e.cn_for_thr(req0.thr) for _, e, _ in padded])
            chols = jnp.stack(
                [e.chol_for(req0.thr, self.config.ridge) for _, e, _ in padded])
            args = (xs, ys, cns, atols, chols)
        elif m == "bakp":
            cns = jnp.stack([e.cn_for_thr(req0.thr) for _, e, _ in padded])
            args = (xs, ys, cns, atols)
        else:  # "bak"
            cns = jnp.stack([e.cn for _, e, _ in padded])
            args = (xs, ys, cns, atols)
        t0 = time.perf_counter()
        res = solver(*args)
        jax.block_until_ready(res.coef)
        dt = time.perf_counter() - t0
        coef = np.asarray(res.coef)
        resid = np.asarray(res.residual)
        for row, (idx, _, hit) in enumerate(singles):
            results[idx] = self._strip(
                requests[idx], coef[row], resid[row], bucket=bucket,
                kind="vmap", group_size=b, latency=dt, hit=hit,
                n_sweeps=res.n_sweeps[row], converged=res.converged[row])
        self.stats.solver_calls += 1
        self.stats.vmap_batches += 1
        self.stats.vmap_requests += b

    def _solve_one(self, requests, idx, entry, hit, bucket, results):
        req = requests[idx]
        obs_real = np.asarray(req.x).shape[0]
        y_pad = pad_y(np.asarray(req.y, np.float32), bucket[0])
        atol = self._padded_atol(req.atol, obs_real, bucket[0])
        t0 = time.perf_counter()
        res = self._call_solver(req, entry, jnp.asarray(y_pad), atol)
        jax.block_until_ready(res.coef)
        dt = time.perf_counter() - t0
        results[idx] = self._strip(
            req, res.coef, res.residual, bucket=bucket, kind="single",
            group_size=1, latency=dt, hit=hit, n_sweeps=res.n_sweeps,
            converged=res.converged)
        self.stats.solver_calls += 1
        self.stats.single_solves += 1
