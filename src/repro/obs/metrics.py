"""Unified metrics registry — Counter / Gauge / Histogram, stdlib only.

One ``MetricsRegistry`` holds every metric family the serving stack emits;
``snapshot()`` returns a plain (JSON-serialisable) dict and
``render_prometheus()`` emits the Prometheus text exposition format, so one
exporter reads the same numbers the engine, dispatcher, cache and kernels
record.  No third-party client library: the container must serve without
new dependencies, and the subset of Prometheus semantics serving needs
(monotonic counters, last-write gauges, fixed-bucket histograms with
labels) is small.

Concurrency: every metric family guards its label→series map and series
state with one lock; the registry guards the name→family map with another.
``snapshot()``/``render_prometheus()`` take the same locks per family, so a
reader never observes a torn histogram (count incremented but sum not).
The serving threads (dispatch, solver, caller threads awaiting tickets)
record concurrently — the hammer test in ``tests/test_obs.py`` holds this.

Global kill switch: ``REPRO_OBS_DISABLED=1`` in the environment makes every
mutator a no-op at import time (``set_enabled`` flips it at runtime, for
tests and A/B overhead runs).  Reads still work — they just see zeros — so
instrumented code never needs its own guard.

Histogram buckets are **fixed and log-spaced** (``log_buckets``): serving
latencies span ~5 decades (a cache-hit vmap member vs a cold 2k×256 solve),
so linear buckets would waste resolution.  Buckets are upper bounds in the
Prometheus ``le`` convention, cumulative when rendered.
"""
from __future__ import annotations

import math
import os
import threading
from collections import OrderedDict
from typing import Dict, Iterable, Optional, Tuple

# --------------------------------------------------------------- kill switch
_TRUTHY = ("1", "true", "yes", "on")


def _env_disabled(environ=None) -> bool:
    env = os.environ if environ is None else environ
    return str(env.get("REPRO_OBS_DISABLED", "")).strip().lower() in _TRUTHY


_enabled = not _env_disabled()


def enabled() -> bool:
    """Whether obs hooks record anything (``REPRO_OBS_DISABLED`` off)."""
    return _enabled


def set_enabled(flag: bool) -> bool:
    """Flip the global obs switch at runtime; returns the previous value."""
    global _enabled
    prev, _enabled = _enabled, bool(flag)
    return prev


# ------------------------------------------------------------------- buckets
def log_buckets(lo: float, hi: float, per_decade: int = 4) -> Tuple[float, ...]:
    """Fixed log-spaced histogram bucket upper bounds covering [lo, hi].

    ``per_decade`` bounds per factor of 10; endpoints included.  The +Inf
    overflow bucket is implicit (every histogram carries it).
    """
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    n = int(math.ceil(round(math.log10(hi / lo) * per_decade, 9)))
    return tuple(lo * 10 ** (i / per_decade) for i in range(n + 1))


#: Default latency buckets: 100µs … 100s, 8 per decade (49 bounds).  Wide
#: enough for a vmap member's share of a warm batch up to a cold mesh solve.
LATENCY_BUCKETS = log_buckets(1e-4, 100.0, per_decade=8)

#: Default count buckets (sweeps, batch sizes): 1 … 1024, 4 per decade.
COUNT_BUCKETS = log_buckets(1.0, 1024.0, per_decade=4)


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    items = [(k, v if type(v) is str else str(v))
             for k, v in labels.items()]
    if len(items) > 1:
        items.sort()
    return tuple(items)


def _label_str(key: Tuple[Tuple[str, str], ...]) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Metric:
    """Base: one named family holding label-keyed series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: "OrderedDict[Tuple, object]" = OrderedDict()

    def labelsets(self):
        with self._lock:
            return list(self._series)

    def labels(self, **labels) -> "_Bound":
        """Bound single-series handle with the label key precomputed.

        The kwargs form (``c.inc(1, kind="vmap")``) rebuilds and sorts the
        label key on every call — fine for per-flush events, measurable for
        per-request ones.  Hot paths fetch a child once per label combo and
        record through it (the serving engine caches these per
        (kind, warm, ...) tuple)."""
        return _Bound(self, _label_key(labels))


class _Bound:
    """Pre-keyed series handle (see ``_Metric.labels``)."""

    __slots__ = ("_m", "_key")

    def __init__(self, metric: "_Metric", key: Tuple):
        self._m = metric
        self._key = key

    def inc(self, n: float = 1.0) -> None:
        if not _enabled:
            return
        self._m._inc_key(self._key, n)

    def set(self, v: float) -> None:
        if not _enabled:
            return
        self._m._set_key(self._key, v)

    def observe(self, v: float) -> None:
        if not _enabled:
            return
        self._m._observe_key(self._key, v)


class Counter(_Metric):
    """Monotonically increasing count (`*_total` families)."""

    kind = "counter"

    def _inc_key(self, key: Tuple, n: float) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + n

    def inc(self, n: float = 1.0, **labels) -> None:
        if not _enabled:
            return
        self._inc_key(_label_key(labels), n)

    def value(self, **labels) -> float:
        """Sum over every series whose labels contain ``labels``."""
        want = set(_label_key(labels))
        with self._lock:
            return sum(v for k, v in self._series.items()
                       if want <= set(k))


class Gauge(_Metric):
    """Last-written value (queue depths, resident entries)."""

    kind = "gauge"

    def _set_key(self, key: Tuple, v: float) -> None:
        with self._lock:
            self._series[key] = float(v)

    def _inc_key(self, key: Tuple, n: float) -> None:
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + n

    def set(self, v: float, **labels) -> None:
        if not _enabled:
            return
        self._set_key(_label_key(labels), v)

    def inc(self, n: float = 1.0, **labels) -> None:
        if not _enabled:
            return
        self._inc_key(_label_key(labels), n)

    def dec(self, n: float = 1.0, **labels) -> None:
        self.inc(-n, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))


class _HistSeries:
    __slots__ = ("counts", "overflow", "total", "sum")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets
        self.overflow = 0
        self.total = 0
        self.sum = 0.0


class Histogram(_Metric):
    """Fixed log-spaced-bucket histogram with sum/count per series."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Optional[Iterable[float]] = None):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets)) if buckets else LATENCY_BUCKETS

    def _observe_key(self, key: Tuple, v: float) -> None:
        v = float(v)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = _HistSeries(len(self.buckets))
            # First bucket whose upper bound holds v (le semantics).
            lo, hi = 0, len(self.buckets)
            while lo < hi:
                mid = (lo + hi) // 2
                if v <= self.buckets[mid]:
                    hi = mid
                else:
                    lo = mid + 1
            if lo < len(self.buckets):
                s.counts[lo] += 1
            else:
                s.overflow += 1
            s.total += 1
            s.sum += v

    def observe(self, v: float, **labels) -> None:
        if not _enabled:
            return
        self._observe_key(_label_key(labels), v)

    def _merged(self, labels) -> _HistSeries:
        """Merge every series whose labels contain ``labels``."""
        want = set(_label_key(labels))
        out = _HistSeries(len(self.buckets))
        with self._lock:
            for k, s in self._series.items():
                if want <= set(k):
                    for i, c in enumerate(s.counts):
                        out.counts[i] += c
                    out.overflow += s.overflow
                    out.total += s.total
                    out.sum += s.sum
        return out

    def count(self, **labels) -> int:
        return self._merged(labels).total

    def sum(self, **labels) -> float:
        return self._merged(labels).sum

    def percentile(self, q: float, **labels) -> float:
        """Estimated q-th percentile (0..100) over matching series.

        Linear interpolation inside the containing bucket — resolution is
        one bucket width (~33% at 8 buckets/decade), which is what a
        fixed-bucket histogram can honestly give.  Returns NaN when empty;
        the top bound when the rank lands in the +Inf overflow bucket.
        """
        s = self._merged(labels)
        if s.total == 0:
            return math.nan
        rank = q / 100.0 * s.total
        seen = 0
        for i, c in enumerate(s.counts):
            if seen + c >= rank and c > 0:
                lo = self.buckets[i - 1] if i else 0.0
                frac = (rank - seen) / c
                return lo + frac * (self.buckets[i] - lo)
            seen += c
        return self.buckets[-1]


class MetricsRegistry:
    """Name → metric family.  ``counter``/``gauge``/``histogram`` get or
    create (idempotent — callers never coordinate registration order);
    re-registering a name as a different kind is a programming error and
    raises."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: "OrderedDict[str, _Metric]" = OrderedDict()

    def _get_or_create(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self):
        with self._lock:
            return list(self._metrics)

    def reset(self) -> None:
        """Zero every family's recorded series IN PLACE (benchmark/test
        isolation).  Registrations survive — components hold direct
        references to their families, so dropping the objects would detach
        them from the registry's snapshot."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            with m._lock:
                m._series.clear()

    # ------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """Plain-dict view of every family: JSON-serialisable, label sets
        flattened to ``"k=v,k2=v2"`` strings (``""`` = unlabelled)."""
        out: dict = {}
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            entry: dict = {"type": m.kind, "help": m.help}
            with m._lock:
                if isinstance(m, Histogram):
                    entry["buckets"] = list(m.buckets)
                    entry["values"] = {
                        _label_str(k): {
                            "counts": list(s.counts) + [s.overflow],
                            "count": s.total,
                            "sum": s.sum,
                        }
                        for k, s in m._series.items()}
                else:
                    entry["values"] = {_label_str(k): v
                                       for k, v in m._series.items()}
            out[m.name] = entry
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            if m.help:
                esc = m.help.replace("\\", r"\\").replace("\n", r"\n")
                lines.append(f"# HELP {m.name} {esc}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            with m._lock:
                series = list(m._series.items())
                if isinstance(m, Histogram):
                    for key, s in series:
                        base = [f'{k}="{_escape_label(v)}"' for k, v in key]
                        cum = 0
                        for le, c in zip(m.buckets, s.counts):
                            cum += c
                            lab = ",".join(base + [f'le="{_fmt(le)}"'])
                            lines.append(f"{m.name}_bucket{{{lab}}} {cum}")
                        lab = ",".join(base + ['le="+Inf"'])
                        lines.append(f"{m.name}_bucket{{{lab}}} {s.total}")
                        suffix = "{" + ",".join(base) + "}" if base else ""
                        lines.append(f"{m.name}_sum{suffix} {_fmt(s.sum)}")
                        lines.append(f"{m.name}_count{suffix} {s.total}")
                else:
                    for key, v in series:
                        lab = ",".join(f'{k}="{_escape_label(val)}"'
                                       for k, val in key)
                        suffix = "{" + lab + "}" if lab else ""
                        lines.append(f"{m.name}{suffix} {_fmt(v)}")
        return "\n".join(lines) + ("\n" if lines else "")


# ------------------------------------------------------------ global default
_default = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-global registry: module-level hooks (kernel dispatch
    counters) and any component not handed an explicit registry record
    here, so one exporter sees the whole stack by default."""
    return _default
