"""Profiler hooks — named regions in TensorBoard/Perfetto traces.

Opt-in wrappers over ``jax.profiler``: ``start_profiling(trace_dir)`` opens
a device trace (``jax.profiler.start_trace``), and ``profile_region(name)``
wraps a code region in ``jax.profiler.TraceAnnotation`` so engine flushes
and fused-kernel launches show up *named* on the trace timeline instead of
as anonymous XLA executions.

Zero-cost when idle: ``profile_region`` is a bare ``yield`` unless a trace
was started (or ``force=True``), so the serving hot path carries only a
module-flag check per region — and nothing at all under
``REPRO_OBS_DISABLED=1``.  jax is imported lazily inside the functions so
``repro.obs`` itself stays importable (and stdlib-only) in tools that never
touch the accelerator stack.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

from repro.obs import metrics as _metrics

_lock = threading.Lock()
_trace_dir: Optional[str] = None


def profiling_active() -> bool:
    """True between ``start_profiling`` and ``stop_profiling``."""
    return _trace_dir is not None


def start_profiling(trace_dir: str) -> bool:
    """Start a jax profiler trace into ``trace_dir`` (TensorBoard /
    ``xprof``-loadable).  Returns False (and stays inert) when obs is
    disabled or jax's profiler is unavailable; raises on a genuinely bad
    start (e.g. a second concurrent trace) so misuse is not silent."""
    global _trace_dir
    if not _metrics.enabled():
        return False
    try:
        from jax import profiler
    except ImportError:
        return False
    with _lock:
        if _trace_dir is not None:
            raise RuntimeError(
                f"profiling already active (writing {_trace_dir!r})")
        profiler.start_trace(trace_dir)
        _trace_dir = trace_dir
    return True


def stop_profiling() -> Optional[str]:
    """Stop the active trace; returns its directory (None if idle)."""
    global _trace_dir
    with _lock:
        if _trace_dir is None:
            return None
        from jax import profiler

        profiler.stop_trace()
        out, _trace_dir = _trace_dir, None
    return out


@contextmanager
def profile_region(name: str, force: bool = False):
    """Name a region on the device trace timeline.

    Inert unless a trace is active (``force=True`` annotates regardless —
    useful when an external tool, not this module, started the trace).
    """
    if not _metrics.enabled() or (_trace_dir is None and not force):
        yield
        return
    try:
        from jax.profiler import TraceAnnotation
    except ImportError:
        yield
        return
    with TraceAnnotation(name):
        yield
