"""repro.obs — telemetry for the solver-serving stack.

Three pieces, all stdlib-only at import time (jax is touched lazily and
only by the profiler hooks):

  metrics.py    Counter / Gauge / Histogram (fixed log-spaced buckets) in a
                thread-safe ``MetricsRegistry``; ``snapshot()`` → plain
                dict, ``render_prometheus()`` → text exposition format.
  trace.py      ``now()`` — THE serving clock (``time.perf_counter``;
                queue-wait and solve-time compose because every component
                reads the same clock); ``span()`` context-manager tracing
                into a ring buffer + optional JSONL sink; ``SolveTelemetry``
                per-request records; the kernel-path relay
                (``record_dispatch``/``consume_dispatch``) that lets the
                engine report which dispatch route a solve *actually* took.
  profiling.py  Opt-in ``profile_region()``/``start_profiling()`` wrapping
                ``jax.profiler`` so flushes and fused-kernel launches show
                up named in TensorBoard/Perfetto traces.
  export.py     ``write_metrics_json`` and the stdlib-``http.server``
                Prometheus scrape endpoint (``start_metrics_server``).

Kill switch: ``REPRO_OBS_DISABLED=1`` makes every hook a no-op (checked per
call; ``set_enabled`` flips it at runtime for A/B overhead runs).

The serving stack (``repro.serve``), the kernel dispatch shims
(``repro.kernels.ops``, ``repro.core.methods``) and the launch drivers all
record here; ``benchmarks/serve_obs.py`` gates the overhead and snapshots
the registry into ``BENCH_obs.json`` in CI.
"""
from repro.obs.export import (MetricsServer, start_metrics_server,
                              write_metrics_json)
from repro.obs.metrics import (COUNT_BUCKETS, LATENCY_BUCKETS, Counter,
                               Gauge, Histogram, MetricsRegistry,
                               default_registry, enabled, log_buckets,
                               set_enabled)
from repro.obs.profiling import (profile_region, profiling_active,
                                 start_profiling, stop_profiling)
from repro.obs.trace import (SolveTelemetry, SpanRecord, Tracer,
                             consume_dispatch, get_tracer, now,
                             record_dispatch, span)

__all__ = [
    "COUNT_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS",
    "MetricsRegistry",
    "MetricsServer",
    "SolveTelemetry",
    "SpanRecord",
    "Tracer",
    "consume_dispatch",
    "default_registry",
    "enabled",
    "get_tracer",
    "log_buckets",
    "now",
    "profile_region",
    "profiling_active",
    "record_dispatch",
    "set_enabled",
    "span",
    "start_metrics_server",
    "start_profiling",
    "stop_profiling",
    "write_metrics_json",
]
