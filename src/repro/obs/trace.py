"""Structured tracing: the serving clock, spans, and solve telemetry.

**The clock.** ``now()`` is THE timestamp source for the serving stack —
``time.perf_counter``.  The engine already timed solves with it while the
dispatcher stamped tickets with ``time.monotonic``; both are monotonic, but
they are distinct clocks with no guaranteed common epoch, so queue-wait
(dispatcher) plus solve-time (engine) did not reliably compose into
end-to-end latency.  Everything now reads ``obs.now()`` so durations and
absolute deadlines live on one timeline.

**Spans.** ``Tracer.span("engine.flush", bucket=..., method=...)`` is a
context manager recording wall time, nesting (per-thread stack → parent
name + depth) and free-form tags into an in-memory ring buffer, with an
optional JSONL sink for offline analysis.  Spans are for *structure* (what
called what, where the time went inside one flush); the aggregate story
lives in the metrics registry.

**SolveTelemetry.** One record per served request — who (tenant), where
(bucket, kernel path, placement), how (warm/cold, batch kind/size), and
outcome (sweeps, SSE, converged, queue wait, deadline margin, error type).
The engine attaches it to every ``ServedSolve``; the async dispatcher
back-fills the queue-side fields on completion.  It is intentionally a
plain dataclass with an ``as_dict()`` — a log pipeline can ship it as-is.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.obs import metrics as _metrics

#: The single serving clock (seconds, monotonic, highest resolution
#: available).  Compare/subtract only against other ``now()`` readings.
now = time.perf_counter


@dataclass
class SpanRecord:
    """One completed (or still-open) span."""

    name: str
    t_start: float
    t_end: Optional[float] = None
    tags: Dict[str, Any] = field(default_factory=dict)
    parent: Optional[str] = None
    depth: int = 0
    thread: str = ""

    @property
    def duration_s(self) -> Optional[float]:
        if self.t_end is None:
            return None
        return self.t_end - self.t_start

    def as_dict(self) -> dict:
        d = asdict(self)
        d["duration_s"] = self.duration_s
        return d


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    return str(v)


class Tracer:
    """Ring-buffered span recorder with per-thread nesting.

    ``capacity`` bounds memory (old spans are dropped, newest kept);
    ``jsonl_path`` (or a later ``set_sink``) additionally appends one JSON
    object per completed span.  Thread-safe: the ring and sink share one
    lock; the nesting stack is thread-local, so spans on different threads
    never see each other as parents.
    """

    def __init__(self, capacity: int = 2048,
                 jsonl_path: Optional[str] = None):
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=capacity)
        self._local = threading.local()
        self._sink = None
        if jsonl_path:
            self.set_sink(jsonl_path)

    # ------------------------------------------------------------- sink
    def set_sink(self, path: Optional[str]) -> None:
        """Point the JSONL sink at ``path`` (None closes it)."""
        with self._lock:
            if self._sink is not None:
                self._sink.close()
                self._sink = None
            if path:
                self._sink = open(path, "a", encoding="utf-8")

    def close(self) -> None:
        self.set_sink(None)

    # ------------------------------------------------------------ record
    @contextmanager
    def span(self, name: str, **tags):
        """Record one span; yields the (mutable) ``SpanRecord`` so the body
        can attach result tags.  No-op (yields None) when obs is disabled."""
        if not _metrics.enabled():
            yield None
            return
        stack: List[SpanRecord] = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        rec = SpanRecord(
            name=name, t_start=now(),
            tags={k: _jsonable(v) for k, v in tags.items()},
            parent=stack[-1].name if stack else None,
            depth=len(stack), thread=threading.current_thread().name)
        stack.append(rec)
        try:
            yield rec
        finally:
            rec.t_end = now()
            stack.pop()
            with self._lock:
                self._ring.append(rec)
                if self._sink is not None:
                    json.dump(rec.as_dict(), self._sink)
                    self._sink.write("\n")
                    self._sink.flush()

    # ------------------------------------------------------------- reads
    def spans(self, name: Optional[str] = None) -> List[SpanRecord]:
        """Completed spans, oldest first (optionally filtered by name)."""
        with self._lock:
            out = list(self._ring)
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()


_tracer = Tracer()


def get_tracer() -> Tracer:
    """The process-global tracer (ring buffer + optional JSONL sink)."""
    return _tracer


def span(name: str, **tags):
    """``get_tracer().span(...)`` — the standard instrumentation call."""
    return _tracer.span(name, **tags)


# -------------------------------------------------------- kernel-path relay
_dispatch_local = threading.local()


def record_dispatch(path: str, method: str = "", reason: str = "") -> None:
    """Note which kernel path a solve actually ran (called from the *eager*
    dispatch shims in ``repro.kernels.ops`` / ``repro.core.methods`` — never
    from code that jit traces, where it would only fire at compile time).

    Increments ``solver_dispatch_total{path,method}`` (and
    ``solver_fallback_total`` when ``reason`` names a fallback cause) on the
    default registry, and parks the path in a thread-local slot the serving
    engine pops (``consume_dispatch``) to stamp the request's
    ``SolveTelemetry.kernel_path`` — the solver call stack has no other
    channel back to the engine.
    """
    if not _metrics.enabled():
        return
    reg = _metrics.default_registry()
    reg.counter("solver_dispatch_total",
                "solver calls by kernel path actually executed").inc(
        1, path=path, method=method or "unknown")
    if reason:
        reg.counter("solver_fallback_total",
                    "solves re-routed off their requested kernel path").inc(
            1, method=method or "unknown", reason=reason)
    _dispatch_local.last = path


def consume_dispatch(default: Optional[str] = None) -> Optional[str]:
    """Pop the kernel path recorded by the last solve on this thread."""
    path = getattr(_dispatch_local, "last", None)
    _dispatch_local.last = None
    return path if path is not None else default


# ------------------------------------------------------------ solve records
@dataclass
class SolveTelemetry:
    """Per-request solve record (see module docstring).

    ``kernel_path`` is the dispatch route that actually executed —
    ``fused`` (whole-solve Pallas megakernel), ``persweep`` (per-sweep
    Pallas launch loop), ``xla`` (jit'd XLA solver), ``sharded`` (mesh
    backend) or ``vmap`` (stacked batch) — including silent fallbacks
    (e.g. a ``bakp_fused`` request whose coalesced width outgrew VMEM and
    re-routed to XLA), which ``method`` alone cannot show.

    ``queue_wait_s`` (submit → batch fire) and ``deadline_margin_s``
    (deadline − completion; negative = missed) are dispatcher-side and stay
    None on the synchronous engine path.  All timestamps/durations are on
    the ``obs.now()`` clock.

    ``retries`` counts the retry-ladder steps the request's solve took
    (``repro.resilience``): 0 = first attempt succeeded; the ``method``/
    ``kernel_path`` fields describe the rung that finally served it.
    """

    request_id: str = ""
    tenant_id: Optional[str] = None
    bucket: Tuple[int, int] = (0, 0)
    method: str = ""
    kernel_path: str = "unknown"
    placement: str = "single"
    lane: str = ""                    # execution-lane label ("single:xla",
    # "mesh:obs_sharded", "serial", ...; "inline" = solved on the caller's
    # thread, e.g. a flush nested inside a lane work)
    batch_kind: str = "single"
    group_size: int = 1
    batch_size: int = 1
    warm_start: bool = False
    cache_hit: bool = False
    n_sweeps: int = 0
    sse: float = 0.0
    converged: bool = False
    retries: int = 0
    solve_s: float = 0.0
    queue_wait_s: Optional[float] = None
    deadline_margin_s: Optional[float] = None
    error_type: Optional[str] = None

    def as_dict(self) -> dict:
        return {k: _jsonable(v) for k, v in asdict(self).items()}
