"""Exporters — JSON snapshots and a Prometheus ``/metrics`` endpoint.

Both read the same ``MetricsRegistry.snapshot()``, so a scraped dashboard
and an archived benchmark artifact can never disagree about what the engine
measured.

``start_metrics_server`` is stdlib ``http.server`` (ThreadingHTTPServer on
a daemon thread): no new dependencies, good enough for a scrape endpoint —
it serves

  * ``/metrics``       — Prometheus text exposition format,
  * ``/metrics.json``  — the snapshot as JSON,
  * ``/healthz``       — liveness probe.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs.metrics import MetricsRegistry, default_registry


def write_metrics_json(path: str,
                       registry: Optional[MetricsRegistry] = None,
                       extra: Optional[dict] = None) -> dict:
    """Write ``registry.snapshot()`` (plus optional ``extra`` metadata under
    ``"meta"``) to ``path`` as JSON; returns the written document."""
    reg = registry or default_registry()
    doc = {"metrics": reg.snapshot()}
    if extra:
        doc["meta"] = extra
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    return doc


class MetricsServer:
    """Handle for a running scrape endpoint (``close()`` to stop)."""

    def __init__(self, registry: MetricsRegistry, host: str, port: int):
        reg = registry

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802  (http.server API)
                if self.path.split("?")[0] == "/metrics":
                    body = reg.render_prometheus().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path.split("?")[0] == "/metrics.json":
                    body = json.dumps(reg.snapshot(), sort_keys=True).encode()
                    ctype = "application/json"
                elif self.path.split("?")[0] == "/healthz":
                    body, ctype = b"ok\n", "text/plain"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # scrapes are not access-log news
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]  # resolved (port=0 OK)
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-metrics-http",
            daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_metrics_server(port: int,
                         registry: Optional[MetricsRegistry] = None,
                         host: str = "0.0.0.0") -> MetricsServer:
    """Serve ``/metrics`` (+ ``/metrics.json``, ``/healthz``) on ``port``
    from a daemon thread.  ``port=0`` binds an ephemeral port (tests);
    read the resolved one off the returned handle."""
    return MetricsServer(registry or default_registry(), host, port)
