"""Adafactor (Shazeer & Stern 2018) — factored second moment, no first
moment: ~4 extra bytes/param (fp32 master) + O(rows+cols) statistics.

Used by the arctic-480b / giant-MoE configs where AdamW state exceeds
single-pod HBM (DESIGN.md §7).  Factoring applies to the trailing two dims
of ≥2-D parameters; 1-D parameters fall back to full second moment.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def _factored(shape) -> bool:
    return len(shape) >= 2


def adafactor_init(params) -> Dict[str, Any]:
    def stat(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {
        "stats": jax.tree_util.tree_map(stat, params),
        "master": jax.tree_util.tree_map(
            lambda x: jnp.array(x, dtype=jnp.float32, copy=True), params),
        "count": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, state, params, *, lr, decay=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    beta2 = 1.0 - cf ** (-decay)

    def upd(g, st, master):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(g.shape):
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
            v_hat = (vr[..., None] / denom[..., None]) * vc[..., None, :]
            update = g / jnp.sqrt(v_hat + eps)
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            update = g / jnp.sqrt(v + eps)
            new_st = {"v": v}
        # update clipping by RMS
        rms = jnp.sqrt(jnp.mean(update * update) + eps)
        update = update / jnp.maximum(1.0, rms / clip_threshold)
        master = master - lr * (update + weight_decay * master)
        return new_st, master

    leaves_g, treedef = jax.tree_util.tree_flatten(grads)
    leaves_s = treedef.flatten_up_to(state["stats"])
    leaves_m = treedef.flatten_up_to(state["master"])
    out = [upd(g, s, m) for g, s, m in zip(leaves_g, leaves_s, leaves_m)]
    new_stats = treedef.unflatten([o[0] for o in out])
    new_master = treedef.unflatten([o[1] for o in out])
    new_params = jax.tree_util.tree_map(
        lambda ma, p: ma.astype(p.dtype), new_master, params)
    return new_params, {"stats": new_stats, "master": new_master,
                        "count": count}
