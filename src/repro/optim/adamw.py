"""AdamW with decoupled weight decay, fp32 master copies and moments.

State pytree mirrors the parameter tree:
  {"m": fp32, "v": fp32, "master": fp32, "count": scalar}
bf16 params are re-quantised from the fp32 master each step (standard
mixed-precision training).  All state leaves share the parameter's sharding,
so with FSDP'd parameters this is ZeRO-sharded automatically.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict[str, Any]:
    # master copies must be distinct buffers even for fp32 params (donation
    # of params + opt_state would otherwise alias the same buffer).
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), t)
    zeros = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.copy, zeros),
            "master": f32(params), "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        master = master - lr * (step + weight_decay * master)
        return m, v, master

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_ma = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, ma) for g, m, v, ma in
           zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    new_params = jax.tree_util.tree_map(
        lambda ma, p: ma.astype(p.dtype), new_master, params)
    return new_params, {"m": new_m, "v": new_v, "master": new_master,
                        "count": count}
