"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup_steps: int,
                    total_steps: int, min_ratio: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup_steps, 1)
    prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup_steps, warm, cos)


def global_norm(tree):
    import jax
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    import jax
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm
