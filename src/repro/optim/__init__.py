"""repro.optim — optimizers (no external deps), schedules, clipping.

AdamW for ≤100B models; Adafactor (factored second moment) for the giant
MoEs where AdamW state does not fit one pod (DESIGN.md §7).  Optimizer
states inherit the parameters' (FSDP × TP) shardings — ZeRO-style state
sharding comes for free.
"""
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.api import make_optimizer
from repro.optim.schedule import cosine_schedule

__all__ = [
    "adafactor_init", "adafactor_update", "adamw_init", "adamw_update",
    "cosine_schedule", "make_optimizer",
]
