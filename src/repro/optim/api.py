"""Optimizer factory keyed by ModelConfig.optimizer."""
from __future__ import annotations

from typing import Callable, Tuple

from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.adamw import adamw_init, adamw_update


def make_optimizer(kind: str) -> Tuple[Callable, Callable]:
    """Returns (init_fn(params) -> state, update_fn(grads, state, params,
    lr=...) -> (params', state'))."""
    if kind == "adamw":
        return adamw_init, adamw_update
    if kind == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(f"unknown optimizer {kind!r}")
