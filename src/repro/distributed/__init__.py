"""repro.distributed — sharding rules, gradient compression, FT monitors."""
