"""Logical-axis → mesh-axis rules (MaxText-style, compact).

One rule table per mesh flavour.  ``pod`` composes with ``data`` for all
batch-like and FSDP sharding so the same model code lowers on the single-pod
(16,16) and multi-pod (2,16,16) meshes.
"""
from __future__ import annotations

from typing import Dict, Tuple, Union

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def make_rules(mesh: Mesh, *, fsdp: bool = True,
               serve: bool = False) -> Dict[str, Axis]:
    """Rules keyed by logical axis name.

    data-like axes map to every non-model mesh axis (so the "pod" axis of the
    multi-pod mesh shards batch/FSDP too — that is what the multi-pod dry-run
    proves out).

    ``serve=True`` switches to weight-stationary sharding: no FSDP (weights
    are never re-gathered per step — the dominant collective at decode), and
    MoE expert weights shard 2-D (experts → model, ff → data axes) so giant
    expert tables still fully shard without per-step gathers.
    """
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    return {
        # activations
        "batch": data_axes,
        "seq": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_mlp": "model",
        "act_vocab": "model",
        "act_experts": "model",
        # weights
        "embed": None if serve else (data_axes if fsdp else None),
        "model": "model",                        # TP dim (heads, mlp, vocab)
        "experts": "model",                      # expert parallelism
        "moe_ff": data_axes if serve else None,  # 2-D EP for serving
        "layers": None,
        "units": None,
        "none": None,
    }


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh: Mesh, axes: Axis) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def divisible_spec(mesh: Mesh, shape, axes_per_dim) -> P:
    """P(...) where any dim whose size does not divide its mapped mesh axes
    falls back to replicated (e.g. batch=1 decode cells)."""
    spec = []
    for dim, ax in zip(shape, axes_per_dim):
        if ax is None or dim % axis_size(mesh, ax):
            spec.append(None)
        else:
            spec.append(ax)
    return P(*spec)


def batch_spec(mesh: Mesh, ndim: int) -> P:
    """P over batch dim 0, replicated elsewhere."""
    return P(data_axes(mesh), *([None] * (ndim - 1)))


def batch_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    return NamedSharding(mesh, batch_spec(mesh, ndim))
