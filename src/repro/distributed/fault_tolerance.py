"""Fault-tolerance / large-scale-operations substrate.

At 1000+ nodes the relevant failure modes are: node loss (→ restart from
checkpoint, possibly on a different mesh), stragglers (→ detect via step-time
outliers), and preemption (→ checkpoint-on-signal).  This module provides
the host-side machinery; the data-plane pieces (elastic re-mesh restore,
resumable data state) live in repro.checkpoint / repro.data.

CheckpointManager   — periodic + on-signal saves, resume, keep-k.
StragglerMonitor    — per-step wall-time ring buffer; flags steps beyond
                      median + k·MAD (the host-level mitigation at pod scale
                      is re-scheduling the slow host's shard; here we surface
                      the signal and count events).
preemption_handler  — SIGTERM → checkpoint-before-exit hook.
"""
from __future__ import annotations

import collections
import signal
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.checkpoint.checkpoint import (latest_step, restore_checkpoint,
                                         save_checkpoint)


class CheckpointManager:
    def __init__(self, directory: str, *, interval_steps: int = 100,
                 keep: int = 3):
        self.directory = directory
        self.interval = interval_steps
        self.keep = keep
        self._preempted = False

    def should_save(self, step: int) -> bool:
        return self._preempted or (step > 0 and step % self.interval == 0)

    def save(self, step: int, tree: Any, extras: Optional[Dict] = None):
        return save_checkpoint(self.directory, step, tree, extras,
                               keep=self.keep)

    def restore_latest(self, template: Any, shardings: Any = None):
        return restore_checkpoint(self.directory, template,
                                  shardings=shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def install_preemption_handler(self):
        def _handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, _handler)


class StragglerMonitor:
    """Step-time outlier detection (median + k·MAD over a sliding window)."""

    def __init__(self, window: int = 64, k: float = 5.0):
        self.times = collections.deque(maxlen=window)
        self.k = k
        self.flagged = 0
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self) -> bool:
        """Returns True if this step is a straggler outlier."""
        dt = time.monotonic() - self._t0
        is_outlier = False
        if len(self.times) >= 8:
            med = float(np.median(self.times))
            mad = float(np.median(np.abs(np.array(self.times) - med))) + 1e-9
            if dt > med + self.k * mad:
                is_outlier = True
                self.flagged += 1
        self.times.append(dt)
        return is_outlier

    def summary(self) -> Dict[str, float]:
        if not self.times:
            return {"median_s": 0.0, "flagged": 0}
        return {"median_s": float(np.median(self.times)),
                "flagged": self.flagged}
