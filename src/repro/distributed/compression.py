"""Gradient compression: int8 quantisation with error feedback.

For cross-pod gradient reduction the wire format matters: the pod axis link
is the DCI bottleneck.  ``compress``/``decompress`` implement per-tensor
symmetric int8 with an error-feedback residual carried in the optimizer
loop (Karimireddy et al. 2019) so the quantisation noise does not bias
convergence.  Applied selectively to the cross-pod psum inside
``train_step`` when ``grad_compression="int8"``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q int8, scale fp32 scalar, new_error fp32)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, gf - deq


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_tree(grads, err_tree):
    """Tree-map compress; returns (q_tree, scale_tree, new_err_tree)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_tree)
    out = [compress(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]),
            treedef.unflatten([o[2] for o in out]))


def decompressed_tree(q_tree, scale_tree):
    return jax.tree_util.tree_map(decompress, q_tree, scale_tree)


def init_error_tree(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
